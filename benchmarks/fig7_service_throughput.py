"""Paper Fig 7: service-level QPS under P99-TBT SLO + HBM bandwidth savings.

Both datasets x both models. Paper: 1.7-2.4x throughput vs packing-only,
1.5-2.4x bandwidth savings. SLO threshold derived from our own stage model at
the paper's reference condition (32 decodes x 4K KV), per the paper's method.

Also reports packing efficiency (scheduled tokens / chunk budget) per
scheduler policy and prefill-concurrency level on the Table II workloads —
multi-prefill packing must never pack worse than the single-prefill baseline
(``fig7pack`` rows, now with tier hit-rate + HBM bytes moved) — and a
swap-vs-recompute preemption comparison under KV pressure (``fig7mem``
rows: tier hit-rate, swap traffic, HBM bytes moved/saved; swap must move
strictly fewer HBM bytes than recompute at the same pressure).
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.serving.workload import ARXIV_SUMMARIZATION, OPENCHAT_SHAREGPT4
from repro.sim.hardware import TPUV6E, TPUV7
from repro.sim.service import qps_under_slo, simulate_service, slo_threshold

SETUPS = [
    ("llama3.1-8b", TPUV6E),
    ("llama3.1-70b", TPUV7),
]
PAPER_RATIO = {  # (model, dataset) -> paper throughput gain
    ("llama3.1-8b", "arxiv_summarization"): 2.4,
    ("llama3.1-8b", "openchat_sharegpt4"): 1.8,
    ("llama3.1-70b", "arxiv_summarization"): 2.0,  # "1.7x-2.4x" band
    ("llama3.1-70b", "openchat_sharegpt4"): 1.7,
}


def bandwidth_savings(hw, cfg, wl, slo, target_qps, n_requests=120):
    """Scale packing-only HBM bw until it matches the prefetch QPS."""
    lo, hi = 1.0, 4.0
    for _ in range(6):
        mid = (lo + hi) / 2
        hw2 = dataclasses.replace(hw, hbm_bw=hw.hbm_bw * mid)
        q, _ = qps_under_slo(hw2, cfg, wl, "packed", slo, n_requests=n_requests, iters=7)
        if q >= target_qps:
            hi = mid
        else:
            lo = mid
    return hi


POLICY_GRID = [  # (label, policy, max_concurrent_prefills)
    ("fcfs_x1", "fcfs", 1),  # single-prefill baseline (the seed's policy)
    ("fcfs_x4", "fcfs", 4),
    ("sjf_x4", "sjf", 4),
]


def packing_efficiency_report(print_fn=print, fast: bool = False):
    """Packing efficiency + tier stats per policy at a fixed load on the
    Table II workloads."""
    n_req = 40 if fast else 100
    print_fn("fig7pack,model,dataset,policy,prefills,pack_eff,preemptions,"
             "tbt_p99_ms,tier_hit,hbm_tb_moved,attn_savings")
    results = {}
    for arch, hw in SETUPS:
        cfg = get_config(arch)
        for wl in (OPENCHAT_SHAREGPT4, ARXIV_SUMMARIZATION):
            for label, policy, n_pf in POLICY_GRID:
                # qps high enough that the prefill lane is contended — the
                # regime where admission order and multi-prefill packing matter
                r = simulate_service(
                    hw, cfg, wl, qps=4.0, mode="packed_prefetch",
                    n_requests=n_req, policy=policy, max_concurrent_prefills=n_pf,
                    kv_block_size=16,
                )
                m = r.metrics
                results[(arch, wl.name, label)] = m["packing_efficiency"]
                print_fn(
                    f"fig7pack,{arch},{wl.name},{policy},{n_pf},"
                    f"{m['packing_efficiency']:.4f},{int(m['preemptions'])},"
                    f"{m['tbt_p99']*1e3:.2f},{m['tier_hit_rate']:.3f},"
                    f"{m['hbm_bytes_moved']/1e12:.2f},"
                    f"{m['attn_padding_savings']:.3f}"
                )
    return results


PREEMPTION_GRID = [  # (preemption mode, admission policy)
    ("recompute", "fcfs"),
    ("swap", "fcfs"),
    ("swap", "sjf"),
]


def preemption_report(print_fn=print, fast: bool = False):
    """Swap vs recompute preemption under KV pressure: tier hit-rate, swap
    traffic, and total HBM bytes moved per mode (acceptance: swap moves
    strictly fewer HBM bytes than recompute at the same pressure)."""
    n_req = 24 if fast else 60
    cfg = get_config("llama3.1-8b")
    hw = TPUV6E
    print_fn("fig7mem,model,dataset,preemption,policy,preemptions,swaps,"
             "tier_hit,swap_gb,hbm_tb_moved,hbm_tb_saved,tbt_p99_ms,"
             "overlap_eff,prefetch_stall_ms")
    results = {}
    for wl in (OPENCHAT_SHAREGPT4,):
        for pre, policy in PREEMPTION_GRID:
            r = simulate_service(
                hw, cfg, wl, qps=2.0, mode="packed_prefetch",
                n_requests=n_req, kv_capacity_tokens=16_000,
                max_decode_batch=16, max_concurrent_prefills=2,
                preemption=pre, policy=policy, kv_block_size=16,
            )
            m = r.metrics
            results[(wl.name, pre, policy)] = m
            print_fn(
                f"fig7mem,llama3.1-8b,{wl.name},{pre},{policy},"
                f"{int(m['preemptions'])},{int(m['swap_outs'])},"
                f"{m['tier_hit_rate']:.3f},{m['swapped_bytes']/1e9:.2f},"
                f"{m['hbm_bytes_moved']/1e12:.2f},{m['hbm_bytes_saved']/1e12:.2f},"
                f"{m['tbt_p99']*1e3:.2f},{m['overlap_efficiency']:.3f},"
                f"{m['prefetch_stall_ms']:.2f}"
            )
    return results


def run(print_fn=print, fast: bool = False):
    n_req = 80 if fast else 150
    iters = 7 if fast else 9
    print_fn("fig7,model,dataset,slo_ms,qps_prefetch,qps_packed,ratio,paper_ratio,bw_savings")
    for arch, hw in SETUPS:
        cfg = get_config(arch)
        slo = slo_threshold(hw, cfg)
        for wl in (OPENCHAT_SHAREGPT4, ARXIV_SUMMARIZATION):
            q_pf, _ = qps_under_slo(hw, cfg, wl, "packed_prefetch", slo,
                                    n_requests=n_req, iters=iters)
            q_pk, _ = qps_under_slo(hw, cfg, wl, "packed", slo,
                                    n_requests=n_req, iters=iters)
            ratio = q_pf / max(q_pk, 1e-9)
            bw = bandwidth_savings(hw, cfg, wl, slo, q_pf, n_requests=n_req)
            paper = PAPER_RATIO[(arch, wl.name)]
            print_fn(
                f"fig7,{arch},{wl.name},{slo*1e3:.2f},{q_pf:.2f},{q_pk:.2f},"
                f"{ratio:.2f},{paper},{bw:.2f}"
            )
    packing_efficiency_report(print_fn, fast=fast)
    preemption_report(print_fn, fast=fast)
    return True


if __name__ == "__main__":
    run()
