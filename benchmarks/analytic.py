"""Analytic per-device FLOPs / HBM-bytes for each dry-run cell.

Why analytic: XLA's ``compiled.cost_analysis()`` counts a while-loop body
ONCE, so any scanned-layer model under-reports FLOPs/bytes by ~n_layers×
(and ×microbatches for grad accumulation). The roofline therefore derives
its terms from exact op dimensions below — the same dimensional accounting
the calibrated simulator uses — and records the raw HLO numbers alongside as
a per-iteration cross-check (see EXPERIMENTS.md §Roofline methodology).

All counts are PER DEVICE on the production mesh (TP=model axis splits
matmul dims; DP=data[×pod] splits tokens; FSDP splits parameter storage).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, ShapeSpec

BF16 = 2
F32 = 4


@dataclasses.dataclass
class CellCost:
    flops: float  # per device
    hbm_bytes: float  # per device
    notes: str = ""


def _attn_flops(cfg: ModelConfig, tokens: int, ctx_avg: float) -> float:
    """Attention score+value matmul flops for `tokens` queries vs ctx_avg keys."""
    n_attn = cfg.n_attn_layers
    if n_attn == 0:
        return 0.0
    if cfg.mla:
        d_qk = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        d_v = cfg.kv_lora_rank
    else:
        d_qk = d_v = cfg.head_dim
    return 2.0 * tokens * ctx_avg * cfg.n_heads * (d_qk + d_v) * n_attn


def _ssm_flops(cfg: ModelConfig, tokens: int) -> float:
    n_ssm = sum(1 for s in cfg.layer_specs if s.mixer in ("mamba1", "mamba2"))
    if n_ssm == 0:
        return 0.0
    d_in = cfg.m_expand * cfg.d_model
    ds = max(cfg.m_d_state, cfg.m_d_state_m1)
    # state update + readout ~ 6 * d_in * d_state per token per layer
    return 6.0 * tokens * d_in * ds * n_ssm


def _linear_flops(cfg: ModelConfig, tokens: int) -> float:
    return 2.0 * cfg.active_param_count() * tokens


def cell_cost(cfg: ModelConfig, shape: ShapeSpec, n_devices: int,
              microbatches: int = 1, remat: bool = True) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    dp = n_devices // 16  # model axis is 16 in both meshes
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()

    if shape.kind == "train":
        tokens = B * S
        fwd = _linear_flops(cfg, tokens) + _attn_flops(cfg, tokens, S / 2) \
            + _ssm_flops(cfg, tokens)
        # bwd = 2x fwd; full remat recomputes fwd once more
        total = fwd * (4.0 if remat else 3.0)
        flops_dev = total / n_devices

        tokens_loc = tokens / dp
        # params: FSDP all-gather writes+reads per microbatch (fwd + bwd), in bf16
        p_tp = n_active * BF16 / 16.0  # after TP split, what one device must see
        param_traffic = 2.0 * 2.0 * p_tp * microbatches
        # optimizer: read p,m,v + write p,m,v in fp32, FSDP-sharded
        opt_traffic = 6.0 * n_total * F32 / n_devices
        grad_traffic = 2.0 * n_total * F32 / n_devices * microbatches
        # activations: ~12 residual-stream r/w per layer (SP-sharded over model)
        act_traffic = 12.0 * cfg.n_layers * tokens_loc * cfg.d_model * BF16 / 16.0
        logits = 2.0 * tokens_loc * cfg.vocab_size * F32 / 16.0
        bytes_dev = param_traffic + opt_traffic + grad_traffic + act_traffic + logits
        return CellCost(flops_dev, bytes_dev, "train: 4x fwd (remat), FSDP+opt traffic")

    if shape.kind == "prefill":
        tokens = B * S
        fwd = _linear_flops(cfg, tokens) + _attn_flops(cfg, tokens, S / 2) \
            + _ssm_flops(cfg, tokens)
        flops_dev = fwd / n_devices
        tokens_loc = tokens / dp
        param_traffic = n_active * BF16 / 16.0  # weights stream once (layer reuse)
        kv_write = cfg.kv_bytes_per_token_layer * cfg.n_attn_layers * tokens_loc / 16.0 \
            if cfg.n_attn_layers else 0.0
        act_traffic = 8.0 * cfg.n_layers * tokens_loc * cfg.d_model * BF16 / 16.0
        bytes_dev = param_traffic + kv_write + act_traffic
        return CellCost(flops_dev, bytes_dev, "prefill: weights once + KV write")

    # decode: one token per request against a cache of S
    tokens = B
    fwd = _linear_flops(cfg, tokens) + _attn_flops(cfg, tokens, S) + _ssm_flops(cfg, tokens)
    flops_dev = fwd / n_devices
    b_loc = max(B / dp, 1.0 / dp if B == 1 else 1.0)  # B=1: SP shards the KV instead
    param_traffic = n_active * BF16 / 16.0  # every weight read for 1 token (the paper's point)
    if cfg.n_attn_layers:
        kv_read = cfg.kv_bytes_per_token_layer * cfg.n_attn_layers * S * B / n_devices \
            if B == 1 else cfg.kv_bytes_per_token_layer * cfg.n_attn_layers * S * b_loc / 16.0
    else:
        kv_read = 0.0
    bytes_dev = param_traffic + kv_read
    return CellCost(flops_dev, bytes_dev, "decode: weights + full KV read")


def collective_multiplier(cfg: ModelConfig, shape: ShapeSpec, microbatches: int) -> float:
    """Trip-count multiplier for collectives parsed inside while bodies
    (per-layer collectives execute n_periods times per [micro]batch pass)."""
    trips = max(cfg.n_periods, 1)
    if shape.kind == "train":
        trips *= 2 * max(microbatches, 1)  # fwd + bwd bodies per microbatch
    return float(trips)
