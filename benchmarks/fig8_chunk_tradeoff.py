"""Paper Fig 8: throughput-latency tradeoff for chunk sizes 512 vs 1024.

arxiv_summarization on Llama3.1-8B: QPS as the P99-TBT SLO relaxes, packing
vs packing-prefetch. Paper: post-saturation gains 1.53x (1024) / 1.39x (512);
up to 3.0x at a tight 31ms SLO.

The sweep prices attention through the unified mixed-batch path: each
prefill chunk reads its paged prefix once per chunk at KV_BLOCK granularity
(sim/opcost.py), the same bytes the engine's kernel streams — so the
chunk-size tradeoff reflects what the unified kernel actually pays, not a
per-token re-read model.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.workload import ARXIV_SUMMARIZATION
from repro.sim.hardware import TPUV6E
from repro.sim.service import qps_under_slo

SLOS_MS = (20.0, 25.0, 31.0, 40.0, 60.0, 100.0)
KV_BLOCK = 128  # page size the unified kernel rounds prefix reads up to


def run(print_fn=print, fast: bool = False):
    cfg = get_config("llama3.1-8b")
    hw = TPUV6E
    n_req = 80 if fast else 150
    iters = 7 if fast else 9
    print_fn("fig8,chunk,slo_ms,qps_prefetch,qps_packed,ratio")
    sat = {}
    for chunk in (512, 1024):
        for slo_ms in SLOS_MS:
            q_pf, _ = qps_under_slo(hw, cfg, ARXIV_SUMMARIZATION, "packed_prefetch",
                                    slo_ms / 1e3, chunk=chunk, n_requests=n_req,
                                    iters=iters, kv_block_size=KV_BLOCK)
            q_pk, _ = qps_under_slo(hw, cfg, ARXIV_SUMMARIZATION, "packed",
                                    slo_ms / 1e3, chunk=chunk, n_requests=n_req,
                                    iters=iters, kv_block_size=KV_BLOCK)
            ratio = q_pf / max(q_pk, 1e-9) if q_pk else float("inf")
            print_fn(f"fig8,{chunk},{slo_ms},{q_pf:.2f},{q_pk:.2f},{ratio:.2f}")
            sat[(chunk, slo_ms)] = (q_pf, q_pk)
    # post-saturation gain (most relaxed SLO)
    for chunk in (512, 1024):
        q_pf, q_pk = sat[(chunk, SLOS_MS[-1])]
        paper = 1.39 if chunk == 512 else 1.53
        print_fn(
            f"fig8,saturated,{chunk},{q_pf:.2f},{q_pk:.2f},"
            f"{q_pf/max(q_pk,1e-9):.2f} (paper {paper})"
        )
    return True


if __name__ == "__main__":
    run()
