"""Calibrate the simulator's free constants against the paper's Fig 5/6 anchors.

The paper's Timeloop-backed cost model has internals we cannot observe
(mapping efficiencies, buffer timing). The mechanism (packing + residual-BW
prefetch + capacity-bounded buffer) is implemented exactly; three scalar
constants remain free and are fitted here by grid search:

    mxu_efficiency      achieved/peak for LLM matmuls
    bw_efficiency       achieved/peak HBM streaming
    prefetch_read_mult  M3D buffer read bw as a multiple of HBM bw

Anchors (Llama3.1-8B on TPUv6e-like, from §V case studies 1-2):
    A1 decode speedup, packing-only,     (P=2048, KV=128K) = 1.41
    A2 decode speedup, packing-prefetch, (P=2048, KV=128K) = 8.06
    A3 overall speedup, packing-prefetch,(P=512,  KV=16K)  = 1.83
    A4 overall speedup, packing-prefetch,(P=1024, KV=16K)  = 1.72
    A5 overall speedup, packing-only,    (P=1024, KV=16K)  = 1.20
    A6 decode speedup @64K, 0MB buffer (packing-only)      = 1.73
    A7 decode speedup @64K, 512MB buffer                   = 6.49
    A8 overall  @64K, 512MB, P=2048                        = 1.35
    A9 overall  @64K, 512MB, P=1024                        = 1.68
Absolute-time anchors (case 3 SLO thresholds — pin the time scale):
    A10 packed-prefetch stage @ (chunk 512 + 32x4K decode), 8B/TPUv6e = 16.70 ms
    A11 same condition, 70B/TPUv7-like                                = 19.23 ms

Run: PYTHONPATH=src python -m benchmarks.calibrate
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
import os

from repro.configs import get_config
from repro.sim import hardware
from repro.sim.stage import decode_latency, simulate_stage

MB = 1024**2
K = 1024


def anchors_error(hw, cfg, hw70=None, cfg70=None, verbose=False):
    def sp(P, ctxs, mode, buf=None, what="decode"):
        serial = simulate_stage(hw, cfg, P, ctxs, "serial")
        if what == "decode":
            d = decode_latency(hw, cfg, P, ctxs, mode, prefetch_buffer=buf)
            return serial.decode_time / d
        r = simulate_stage(hw, cfg, P, ctxs, mode, prefetch_buffer=buf)
        return serial.stage_time / r.stage_time

    ctx128 = [4 * K] * 32
    ctx64 = [4 * K] * 16
    ctx16 = [4 * K] * 4
    preds = {
        "A1": (sp(2048, ctx128, "packed"), 1.41),
        "A2": (sp(2048, ctx128, "packed_prefetch"), 8.06),
        "A3": (sp(512, ctx16, "packed_prefetch", what="overall"), 1.83),
        "A4": (sp(1024, ctx16, "packed_prefetch", what="overall"), 1.72),
        "A5": (sp(1024, ctx16, "packed", what="overall"), 1.20),
        "A6": (sp(2048, ctx64, "packed_prefetch", buf=0.0), 1.73),
        "A7": (sp(2048, ctx64, "packed_prefetch", buf=512 * MB), 6.49),
        "A8": (sp(2048, ctx64, "packed_prefetch", buf=512 * MB, what="overall"), 1.35),
        "A9": (sp(1024, ctx64, "packed_prefetch", buf=512 * MB, what="overall"), 1.68),
    }
    preds["A10"] = (
        simulate_stage(hw, cfg, 512, [4 * K] * 32, "packed_prefetch").stage_time * 1e3,
        16.70,
    )
    if hw70 is not None:
        preds["A11"] = (
            simulate_stage(hw70, cfg70, 512, [4 * K] * 32, "packed_prefetch").stage_time * 1e3,
            19.23,
        )
    err = 0.0
    for name, (got, want) in preds.items():
        err += (math.log(got) - math.log(want)) ** 2
        if verbose:
            print(f"  {name}: sim={got:6.2f} paper={want:5.2f}  ({100*(got/want-1):+5.1f}%)")
    return math.sqrt(err / len(preds)), preds


def main():
    cfg = get_config("llama3.1-8b")
    cfg70 = get_config("llama3.1-70b")
    best = None
    for mxu, bw, mult in itertools.product(
        (0.40, 0.50, 0.60, 0.70, 0.80, 0.90, 1.0),
        (0.70, 0.80, 0.90, 1.0),
        (2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 32.0),
    ):
        hw = dataclasses.replace(
            hardware.TPUV6E, mxu_efficiency=mxu, bw_efficiency=bw, prefetch_read_mult=mult
        )
        hw70 = dataclasses.replace(
            hardware.TPUV7, mxu_efficiency=mxu, bw_efficiency=bw, prefetch_read_mult=mult
        )
        err, _ = anchors_error(hw, cfg, hw70, cfg70)
        if best is None or err < best[0]:
            best = (err, mxu, bw, mult)
    err, mxu, bw, mult = best
    print(f"best: mxu_eff={mxu} bw_eff={bw} prefetch_read_mult={mult} rms_log_err={err:.3f}")
    hw = dataclasses.replace(
        hardware.TPUV6E, mxu_efficiency=mxu, bw_efficiency=bw, prefetch_read_mult=mult
    )
    hw70 = dataclasses.replace(
        hardware.TPUV7, mxu_efficiency=mxu, bw_efficiency=bw, prefetch_read_mult=mult
    )
    _, preds = anchors_error(hw, cfg, hw70, cfg70, verbose=True)
    out = {
        "mxu_efficiency": mxu,
        "bw_efficiency": bw,
        "prefetch_read_mult": mult,
        "rms_log_err": err,
        "anchors": {k: {"sim": float(v[0]), "paper": v[1]} for k, v in preds.items()},
    }
    path = os.path.join(os.path.dirname(__file__), "calibration.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
